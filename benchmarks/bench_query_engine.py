"""Multi-predicate query benchmark: the planned scan engine (shared
per-chunk pyramid + selectivity x cost predicate ordering + masked
evaluation + static-shape batching) vs the seed workflow of naive
per-predicate full scans. Writes ``BENCH_query_engine.json`` at the repo
root.

  PYTHONPATH=src python -m benchmarks.bench_query_engine [--quick]

Protocol: one TAHOMA system per concept (trained once, small grid), a
3-predicate + metadata query planned under CAMERA, then both executors
timed WARM (jit compiled, virtual columns reset) at two corpus sizes.
Row sets must be bit-identical (make_multi_corpus quantizes to the
uint8 dyadic regime, so pyramid derivation is exact — DESIGN.md §3.1).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs.base import TahomaCNNConfig                    # noqa: E402
from repro.core.pipeline import initialize_system                 # noqa: E402
from repro.core.transforms import Representation                  # noqa: E402
from repro.data.synthetic import (DEFAULT_PREDICATES, make_corpus,  # noqa: E402
                                  make_multi_corpus, three_way_split)
from repro.engine import (PredicateClause, QuerySpec, ScanEngine,  # noqa: E402
                          naive_scan, plan_query)

OUT = Path(__file__).resolve().parents[1] / "BENCH_query_engine.json"


def build_systems(specs, *, steps: int, n_train: int, hw: int, log=print):
    reps = [Representation(8, "gray"), Representation(16, "gray"),
            Representation(hw, "rgb")]
    archs = [TahomaCNNConfig(1, 8, 16)]
    systems = {}
    t0 = time.time()
    for spec in specs:
        x, y = make_corpus(spec, n_train, hw=hw, seed=0)
        systems[spec.name] = initialize_system(
            *three_way_split(x, y, seed=1), archs, reps, steps=steps)
    log(f"[bench] trained {sum(len(s.bank.entries) for s in systems.values())}"
        f" models in {time.time() - t0:.0f}s")
    return systems


def bench_corpus(systems, specs, n_rows: int, *, chunk: int,
                 scenario: str, repeats: int = 3, log=print) -> dict:
    qx, qlabels = make_multi_corpus(specs, n_rows, hw=32, seed=7,
                                    positive_rate=0.4)
    metadata = {"cam": np.arange(n_rows) % 2}
    spec_q = QuerySpec(
        metadata_eq={"cam": 0},
        predicates=[PredicateClause(s.name, min_accuracy=0.8)
                    for s in specs])
    plan = plan_query(systems, spec_q, scenario=scenario,
                      metadata=metadata)
    log(plan.explain(n_rows=n_rows))

    engine = ScanEngine(qx, metadata, chunk=chunk)
    naive_fns: dict = {}

    def run_engine():
        engine.reset_cache()      # fresh virtual columns: full query work
        return engine.execute(plan.cascades, plan.metadata_eq)

    def run_naive():
        return naive_scan(qx, plan.cascades, metadata, plan.metadata_eq,
                          chunk=chunk, _fn_cache=naive_fns)

    res = run_engine()            # warm: jit compile both paths
    ref = run_naive()
    identical = bool(np.array_equal(res.indices, ref))

    t_eng = min(_time(run_engine) for _ in range(repeats))
    t_nai = min(_time(run_naive) for _ in range(repeats))
    rows_eval = res.stats.rows_evaluated
    naive_rows = n_rows * len(specs)
    out = {
        "rows": n_rows,
        "chunk": chunk,
        "predicates": len(specs),
        "matches": int(len(res.indices)),
        "identical_row_sets": identical,
        "engine_s": round(t_eng, 4),
        "naive_s": round(t_nai, 4),
        "speedup_x": round(t_nai / t_eng, 2),
        "rows_evaluated_engine": int(rows_eval),
        "rows_evaluated_naive": int(naive_rows),
        "row_eval_ratio_x": round(naive_rows / max(rows_eval, 1), 2),
        "stages": [{
            "concept": s.concept, "rows_in": s.rows_in,
            "rows_evaluated": s.rows_evaluated, "batches": s.batches}
            for s in res.stats.stages],
    }
    log(f"  rows={n_rows}: engine {t_eng:.3f}s vs naive {t_nai:.3f}s "
        f"-> {out['speedup_x']}x (row-evals {out['row_eval_ratio_x']}x "
        f"fewer, identical={identical})")
    return out


def _time(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller corpora/training (CI smoke)")
    args = ap.parse_args()

    import jax
    specs = DEFAULT_PREDICATES[:3]
    steps = 30 if args.quick else 60
    sizes = (256, 512) if args.quick else (768, 2304)
    chunk = 64 if args.quick else 128

    systems = build_systems(specs, steps=steps,
                            n_train=160 if args.quick else 240, hw=32)
    report = {
        "backend": jax.default_backend(),
        "scenario": "CAMERA",
        "query": "SELECT frames WHERE cam=0 AND "
                 + " AND ".join(f"contains({s.name})" for s in specs),
        "corpora": [bench_corpus(systems, specs, n, chunk=chunk,
                                 scenario="CAMERA") for n in sizes],
    }
    report["speedup_min_x"] = min(c["speedup_x"]
                                  for c in report["corpora"])
    report["all_identical"] = all(c["identical_row_sets"]
                                  for c in report["corpora"])
    # --quick is a CI smoke: compile-dominated, never clobber the artifact
    out = OUT.with_suffix(".quick.json") if args.quick else OUT
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
