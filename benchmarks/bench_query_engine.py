"""Multi-predicate query benchmark: the planned scan engine (shared
per-chunk pyramid + selectivity x cost predicate ordering + masked
evaluation + static-shape batching) vs the seed workflow of naive
per-predicate full scans, PLUS the joint cascade-set optimizer
(DESIGN.md §11) vs independent per-predicate selection — both plans
executed end-to-end on the same engine. Writes
``BENCH_query_engine.json`` at the repo root.

  PYTHONPATH=src python -m benchmarks.bench_query_engine [--quick]

With ``--shards`` it instead benchmarks the sharded scan engine
(DESIGN.md §9) across shard counts on 8 simulated host devices, records
per-shard stage stats, and writes ``BENCH_sharded_scan.json``:

  PYTHONPATH=src python -m benchmarks.bench_query_engine --shards [1,2,4,8]

Protocol: one TAHOMA system per concept (trained once, small grid), a
3-predicate + metadata query planned under CAMERA, then both executors
timed WARM (jit compiled, virtual columns reset) at two corpus sizes.
Row sets must be bit-identical (make_multi_corpus quantizes to the
uint8 dyadic regime, so pyramid derivation is exact — DESIGN.md §3.1).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# the sharded bench simulates a multi-chip host; the device-count flag
# must land before the repro imports below pull jax in
from repro.launch.devsim import force_host_devices  # noqa: E402

force_host_devices(8, when_flag="--shards")

from repro.configs.base import TahomaCNNConfig                    # noqa: E402
from repro.core.pipeline import initialize_system                 # noqa: E402
from repro.core.transforms import Representation                  # noqa: E402
from repro.data.synthetic import (DEFAULT_PREDICATES, make_corpus,  # noqa: E402
                                  make_multi_corpus, three_way_split)
from repro.engine import (PredicateClause, QuerySpec, ScanEngine,  # noqa: E402
                          ShardedScanEngine, naive_scan, plan_query)

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_query_engine.json"
OUT_SHARDED = ROOT / "BENCH_sharded_scan.json"
# --quick is a CI smoke: compile-dominated numbers land under artifacts/,
# never clobbering the repo-root headline artifacts
QUICK_DIR = ROOT / "artifacts" / "bench"


def _quick_path(out: Path) -> Path:
    QUICK_DIR.mkdir(parents=True, exist_ok=True)
    return QUICK_DIR / out.with_suffix(".quick.json").name


def build_systems(specs, *, steps: int, n_train: int, hw: int, log=print,
                  rich_grid: bool = False, recalibrate: bool = False):
    """rich_grid widens the model grid (both colors at every resolution,
    two architectures) so per-concept Pareto frontiers are big enough
    for the joint-vs-independent comparison to have room to diverge."""
    if rich_grid:
        reps = [Representation(8, "gray"), Representation(8, "rgb"),
                Representation(16, "gray"), Representation(16, "rgb"),
                Representation(hw, "rgb")]
        archs = [TahomaCNNConfig(1, 8, 16), TahomaCNNConfig(2, 16, 32)]
    else:
        reps = [Representation(8, "gray"), Representation(16, "gray"),
                Representation(hw, "rgb")]
        archs = [TahomaCNNConfig(1, 8, 16)]
    systems = {}
    t0 = time.time()
    for spec in specs:
        x, y = make_corpus(spec, n_train, hw=hw, seed=0)
        systems[spec.name] = initialize_system(
            *three_way_split(x, y, seed=1), archs, reps, steps=steps)
    log(f"[bench] trained {sum(len(s.bank.entries) for s in systems.values())}"
        f" models in {time.time() - t0:.0f}s")
    if rich_grid:
        _stabilize_profiles(systems, recalibrate=recalibrate)
    return systems


CALIBRATION = Path(__file__).resolve().parent / \
    "calibrated_infer_costs.json"


def _stabilize_profiles(systems, recalibrate: bool = False) -> None:
    """Per-model inference costs are MEASURED per system
    (core/pipeline.profile_infer_costs); run-to-run jitter on this box
    is large enough (observed up to ~1.6x on the trusted model) to flip
    Pareto frontiers, making the planned cascade sets — and therefore
    the joint-vs-independent comparison — nondeterministic. The rich
    grid's per-model costs are therefore PINNED from
    ``benchmarks/calibrated_infer_costs.json`` (committed; measured on
    a quiet container of this class — median of the init-time
    measurements across the three per-concept systems, which train the
    same grid) and the scenario profiles + evaluated-space caches are
    rebuilt from them. Engine/naive timings stay fully
    measured — only the PLANNER's inputs are pinned, exactly like the
    paper's use of profiled constants. ``--recalibrate`` (or a missing
    file) re-measures on this host and rewrites the file."""
    import numpy as np

    from repro.core.costs import CostProfile

    names = list(next(iter(systems.values())).bank.names)
    if CALIBRATION.exists() and not recalibrate:
        stable = json.loads(CALIBRATION.read_text())
        missing = [n for n in names if n not in stable]
        if missing:
            raise SystemExit(
                f"calibrated_infer_costs.json lacks {missing}; rerun "
                f"with --recalibrate after changing the bench grid")
    else:
        stable = {n: float(np.median([s.infer_s[n]
                                      for s in systems.values()]))
                  for n in names}
        CALIBRATION.write_text(json.dumps(stable, indent=2) + "\n")
        print(f"[bench] wrote {CALIBRATION}")
    for s in systems.values():
        s.infer_s = {n: float(stable[n]) for n in names}
        s.profile = CostProfile.modeled(
            s.infer_s, list(set(s.bank.reps)),
            base_hw=s.bank.entries[0].rep.resolution
            if s.profile.base_hw is None else s.profile.base_hw)
        s.space_cache.clear()
        s.dec_cache.clear()


def bench_corpus(systems, specs, n_rows: int, *, chunk: int,
                 scenario: str, repeats: int = 3, log=print) -> dict:
    qx, qlabels = make_multi_corpus(specs, n_rows, hw=32, seed=7,
                                    positive_rate=0.4)
    metadata = {"cam": np.arange(n_rows) % 2}
    # floor 0.9: with the pinned calibration this is where the full
    # grid's frontiers offer real joint-vs-independent alternatives;
    # the --quick grid trains too small for it and falls back
    plan = plan_joint = None
    for floor in (0.9, 0.8, None):
        spec_q = QuerySpec(
            metadata_eq={"cam": 0},
            predicates=[PredicateClause(s.name, min_accuracy=floor)
                        for s in specs])
        try:
            plan = plan_query(systems, spec_q, scenario=scenario,
                              metadata=metadata)
            plan_joint = plan_query(systems, spec_q, scenario=scenario,
                                    metadata=metadata, joint=True)
            break
        except ValueError:
            log(f"[bench] no cascade clears min_accuracy={floor}; "
                f"relaxing")
    log(plan.explain(n_rows=n_rows))
    log(plan_joint.explain(n_rows=n_rows))

    engine = ScanEngine(qx, metadata, chunk=chunk)
    naive_fns: dict = {}

    def run_engine(p):
        engine.reset_cache()      # fresh virtual columns: full query work
        return engine.execute(p.cascades, p.metadata_eq)

    def run_naive():
        return naive_scan(qx, plan.cascades, metadata, plan.metadata_eq,
                          chunk=chunk, _fn_cache=naive_fns)

    res = run_engine(plan)        # warm: jit compile all three paths
    res_joint = run_engine(plan_joint)
    ref = run_naive()
    identical = bool(np.array_equal(res.indices, ref))
    # the joint plan may legitimately select DIFFERENT cascades (both
    # satisfy the accuracy floor), so its row set is checked against its
    # OWN naive reference; agreement with the independent plan's rows is
    # reported, not asserted
    ref_joint = naive_scan(qx, plan_joint.cascades, metadata,
                           plan_joint.metadata_eq, chunk=chunk,
                           _fn_cache=naive_fns)
    joint_identical = bool(np.array_equal(res_joint.indices, ref_joint))

    t_eng = min(_time(lambda: run_engine(plan)) for _ in range(repeats))
    t_joint = min(_time(lambda: run_engine(plan_joint))
                  for _ in range(repeats))
    t_nai = min(_time(run_naive) for _ in range(repeats))
    # res/res_joint from the warm runs are still valid: reset_cache()
    # makes every run identical full work, so stats are deterministic
    rows_eval = res.stats.rows_evaluated
    naive_rows = n_rows * len(specs)
    out = {
        "rows": n_rows,
        "chunk": chunk,
        "predicates": len(specs),
        "min_accuracy": floor,
        "matches": int(len(res.indices)),
        "identical_row_sets": identical,
        "engine_s": round(t_eng, 4),
        "naive_s": round(t_nai, 4),
        "speedup_x": round(t_nai / t_eng, 2),
        "rows_evaluated_engine": int(rows_eval),
        "rows_evaluated_naive": int(naive_rows),
        "row_eval_ratio_x": round(naive_rows / max(rows_eval, 1), 2),
        "stages": [{
            "concept": s.concept, "rows_in": s.rows_in,
            "rows_evaluated": s.rows_evaluated, "batches": s.batches}
            for s in res.stats.stages],
        "joint": {
            "costing": plan_joint.costing,
            "engine_s": round(t_joint, 4),
            "joint_vs_independent_x": round(t_eng / t_joint, 2),
            "identical_rows_vs_own_naive": joint_identical,
            "same_rows_as_independent": bool(
                np.array_equal(res_joint.indices, res.indices)),
            "same_cascades_as_independent": (
                [c.key for c in plan_joint.cascades]
                == [c.key for c in plan.cascades]),
            "matches": int(len(res_joint.indices)),
            "rows_evaluated": int(res_joint.stats.rows_evaluated),
            "level_set_independent": list(plan.level_set),
            "level_set_joint": list(plan_joint.level_set),
            # estimate keys name their cost model: the independent plan
            # is priced by the paper's §VI reach-weighted walk, the
            # joint plan by its own costing mode (engine-dense by
            # default) — they are NOT directly comparable numbers; the
            # measured engine_s above is the apples-to-apples result
            "est_paper_cost_per_row_independent_us": round(
                plan.estimated_cost_per_row() * 1e6, 2),
            "est_joint_cost_per_row_us": round(
                plan_joint.estimated_cost_per_row() * 1e6, 2),
            "est_joint_unshared_cost_per_row_us": round(
                plan_joint.unshared_cost_per_row() * 1e6, 2),
        },
    }
    log(f"  rows={n_rows}: engine {t_eng:.3f}s vs naive {t_nai:.3f}s "
        f"-> {out['speedup_x']}x (row-evals {out['row_eval_ratio_x']}x "
        f"fewer, identical={identical})")
    log(f"  rows={n_rows}: joint plan {t_joint:.3f}s vs independent "
        f"{t_eng:.3f}s -> {out['joint']['joint_vs_independent_x']}x "
        f"(levels {out['joint']['level_set_joint']} vs "
        f"{out['joint']['level_set_independent']}, joint-identical="
        f"{joint_identical})")
    return out


def _time(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _shard_critical_path(eng, cascades, shard_plan, n_corpus: int,
                         repeats: int) -> list[float]:
    """Per-shard scan seconds, each shard run in isolation through the
    serial shard unit (ScanEngine.scan_rows) against a fresh store —
    i.e. the time the shard's own device pipeline is busy. On a real
    N-device host the shards run concurrently and the scan completes in
    max(per-shard time); on this CI simulator the forced host devices
    share a couple of physical cores, so wall-clock concurrency is
    unmeasurable noise and the critical path is the reproducible
    throughput measure."""
    from repro.engine.scan import VirtualColumnStore

    times = []
    for part in shard_plan.shards:
        if not len(part):
            times.append(0.0)
            continue
        eng.local.scan_rows(cascades, part,
                            store=VirtualColumnStore(n_corpus))  # warm
        times.append(min(
            _time(lambda: eng.local.scan_rows(
                cascades, part, store=VirtualColumnStore(n_corpus)))
            for _ in range(repeats)))
    return times


def bench_sharded(systems, specs, n_rows: int, shard_counts, *,
                  chunk: int, scenario: str, repeats: int = 3,
                  log=print) -> dict:
    """Scaling curve of the sharded engine (same planned query, same
    corpus): every shard count runs the identical code paths — shards=1
    is the single-shard baseline of the curve — plus the unsharded
    ScanEngine as the reference row set and absolute anchor.

    Two timings per shard count: ``wall_s`` (the lockstep execute on
    this host — on shared-core CPU CI the simulated devices compete for
    the same cores, so this cannot scale and is noisy) and the
    per-device critical path (max isolated per-shard scan time — what
    an N-device host's wall-clock converges to). ``rows_per_s`` and the
    headline scaling use the critical path."""
    import jax

    qx, _ = make_multi_corpus(specs, n_rows, hw=32, seed=7,
                              positive_rate=0.4)
    metadata = {"cam": np.arange(n_rows) % 2}
    spec_q = QuerySpec(
        metadata_eq={"cam": 0},
        predicates=[PredicateClause(s.name, min_accuracy=0.8)
                    for s in specs])
    try:
        plan = plan_query(systems, spec_q, scenario=scenario,
                          metadata=metadata)
    except ValueError:
        # --quick trains a grid too small to clear the accuracy bar
        # (training under the forced multi-device host also shifts the
        # numerics slightly); the scaling curve doesn't need it
        log("[bench] no cascade clears min_accuracy=0.8 (quick grid); "
            "re-planning unconstrained")
        spec_q = QuerySpec(metadata_eq={"cam": 0},
                           predicates=[PredicateClause(s.name)
                                       for s in specs])
        plan = plan_query(systems, spec_q, scenario=scenario,
                          metadata=metadata)

    ref_engine = ScanEngine(qx, metadata, chunk=chunk)
    ref_res = ref_engine.execute(plan.cascades, plan.metadata_eq)  # warm
    t_ref = min(_time(lambda: (ref_engine.reset_cache(),
                               ref_engine.execute(plan.cascades,
                                                  plan.metadata_eq)))
                for _ in range(repeats))
    rows_scanned = ref_res.stats.rows_scanned

    curve = []
    for k in shard_counts:
        eng = ShardedScanEngine(qx, metadata, shards=k, chunk=chunk)
        shard_plan = eng.plan_for(plan.cascades, plan.metadata_eq)
        log(plan.explain(n_rows=n_rows, shard_plan=shard_plan)
            if k == max(shard_counts) else
            f"[bench] shards={k}: {shard_plan.describe()}")
        res = eng.execute(plan.cascades, plan.metadata_eq)         # warm
        identical = bool(np.array_equal(res.indices, ref_res.indices))
        if not identical:       # record the divergence, don't hide it
            log(f"[bench] ERROR: sharded row set diverged at {k} shards")
        t_wall = min(_time(lambda: (eng.reset_cache(),
                                    eng.execute(plan.cascades,
                                                plan.metadata_eq)))
                     for _ in range(repeats))
        shard_s = _shard_critical_path(eng, plan.cascades, shard_plan,
                                       len(qx), repeats)
        crit = max(shard_s)
        entry = {
            "shards": k,
            "devices": res.stats.n_devices,
            "strategy": shard_plan.strategy,
            "balance": round(shard_plan.balance, 3),
            "wall_s": round(t_wall, 4),
            "wall_rows_per_s": round(rows_scanned / t_wall, 1),
            "shard_critical_path_s": round(crit, 4),
            "rows_per_s": round(rows_scanned / crit, 1),
            "shard_scan_s": [round(t, 4) for t in shard_s],
            "rows_evaluated": int(res.stats.rows_evaluated),
            "supersteps": int(res.stats.supersteps),
            "identical_row_sets": identical,
            "per_shard": [{
                "rows": sh.rows_scanned,
                "chunks": sh.chunks,
                "stages": [{
                    "concept": st.concept, "rows_in": st.rows_in,
                    "rows_cached": st.rows_cached,
                    "rows_evaluated": st.rows_evaluated,
                    "batches": st.batches} for st in sh.stages],
            } for sh in res.stats.shards],
        }
        curve.append(entry)
        log(f"  shards={k}: critical path {crit:.3f}s "
            f"-> {entry['rows_per_s']:.0f} rows/s  (wall {t_wall:.3f}s, "
            f"{res.stats.supersteps} supersteps, "
            f"balance {entry['balance']})")

    base = next(c for c in curve if c["shards"] == min(shard_counts))
    peak = next(c for c in curve if c["shards"] == max(shard_counts))
    return {
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "physical_cores": os.cpu_count(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "throughput_metric":
            "rows_past_metadata / max(isolated per-shard scan time): the "
            "per-device critical path an N-device host's wall-clock "
            "converges to. wall_s is also reported; on this CI simulator "
            "all forced host devices share the physical cores, so wall_s "
            "cannot scale with shard count and is noise-dominated.",
        "rows": n_rows,
        "rows_past_metadata": int(rows_scanned),
        "chunk": chunk,
        "predicates": len(specs),
        "scenario": scenario,
        "unsharded_engine_s": round(t_ref, 4),
        "unsharded_rows_per_s": round(rows_scanned / t_ref, 1),
        "curve": curve,
        "throughput_scaling_x": round(
            peak["rows_per_s"] / base["rows_per_s"], 2),
        "all_identical": all(c["identical_row_sets"] for c in curve),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller corpora/training (CI smoke)")
    ap.add_argument("--shards", nargs="?", const="1,2,4,8", default=None,
                    help="bench the sharded engine at these shard counts "
                         "(comma-separated; default 1,2,4,8) and write "
                         "BENCH_sharded_scan.json")
    ap.add_argument("--chunk", type=int, default=None,
                    help="override the per-shard chunk size")
    ap.add_argument("--recalibrate", action="store_true",
                    help="re-measure the pinned per-model inference "
                         "costs (benchmarks/calibrated_infer_costs.json)"
                         " on this host instead of using the committed "
                         "calibration")
    args = ap.parse_args()

    import jax
    specs = DEFAULT_PREDICATES[:3]
    steps = 30 if args.quick else 60
    sizes = (256, 512) if args.quick else (768, 2304)
    # the sharded curve runs at the engine's default chunk (64): shard
    # worklists shrink as 1/k, so the per-shard chunk is the knob that
    # keeps late-stage slabs dense
    chunk = args.chunk or (64 if (args.quick or args.shards is not None)
                           else 128)

    systems = build_systems(specs, steps=steps,
                            n_train=160 if args.quick else 240, hw=32,
                            rich_grid=args.shards is None,
                            recalibrate=args.recalibrate)

    if args.shards is not None:
        if jax.device_count() == 1:
            # e.g. an argparse prefix spelling (--shard) slipped past the
            # pre-import bootstrap's exact --shards match
            print("[bench] WARNING: only 1 JAX device visible — the "
                  "device-count bootstrap did not run (spell the flag "
                  "--shards); curve will have no device spread")
        shard_counts = [int(s) for s in args.shards.split(",")]
        report = bench_sharded(systems, specs,
                               sizes[-1], shard_counts,
                               chunk=chunk, scenario="CAMERA")
        out = _quick_path(OUT_SHARDED) if args.quick else OUT_SHARDED
        out.write_text(json.dumps(report, indent=2) + "\n")
        print(f"wrote {out}  (throughput scaling "
              f"{report['throughput_scaling_x']}x at "
              f"{max(shard_counts)} shards)")
        return

    report = {
        "backend": jax.default_backend(),
        "scenario": "CAMERA",
        "query": "SELECT frames WHERE cam=0 AND "
                 + " AND ".join(f"contains({s.name})" for s in specs),
        "corpora": [bench_corpus(systems, specs, n, chunk=chunk,
                                 scenario="CAMERA") for n in sizes],
    }
    report["speedup_min_x"] = min(c["speedup_x"]
                                  for c in report["corpora"])
    report["all_identical"] = all(c["identical_row_sets"]
                                  for c in report["corpora"])
    report["joint_speedup_min_x"] = min(
        c["joint"]["joint_vs_independent_x"] for c in report["corpora"])
    report["joint_all_identical_vs_own_naive"] = all(
        c["joint"]["identical_rows_vs_own_naive"]
        for c in report["corpora"])
    out = _quick_path(OUT) if args.quick else OUT
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
