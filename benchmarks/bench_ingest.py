"""Streaming ingest-time indexing benchmark (DESIGN.md §14): how much
query-time work does ingest-time indexing (temporal skip detector +
stage-0 candidate-concept index, engine/ingest.py) remove from a
multi-predicate query vs the cold scan — and does the exactness escape
hatch hold? Writes ``BENCH_ingest.json`` at the repo root.

  PYTHONPATH=src python -m benchmarks.bench_ingest [--quick]

Protocol: one TAHOMA system per concept, a joint-planned 3-predicate
query; a simulated camera stream (piecewise-constant scenes + dyadic
sensor jitter) is ingested chunk-by-chunk; the SAME planned query then
runs three ways on fresh engines:

  cold           — no index, full scan (the baseline);
  indexed exact  — ingest-decided stage-0 labels seed the store and
                   prune decided-0 rows; skip-aliased rows re-verified.
                   The row set MUST be bit-identical to the cold scan
                   and naive_scan (SystemExit otherwise — this is the
                   CI exactness gate);
  indexed approx — skip-alias label propagation + candidate pruning at
                   the pinned recall knob (prune_margin 0.25). The
                   headline: fraction of query-time model invocations
                   (rows evaluated through any cascade stage)
                   eliminated vs cold, at the measured recall.

Timings are WARM (jit compiled); work metrics (rows evaluated, chunks)
are deterministic. A serving block seeds an AsyncCascadeService from
the index and reports the store-hit rate over the full corpus."""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))

from bench_query_engine import build_systems  # noqa: E402

from repro.core.pipeline import build_ingest_pipeline  # noqa: E402
from repro.data.synthetic import (DEFAULT_PREDICATES,  # noqa: E402
                                  make_camera_stream)
from repro.engine import (PredicateClause, QuerySpec, ScanEngine,  # noqa: E402
                          naive_scan, plan_query)
from repro.engine.ingest import indexed_execute  # noqa: E402

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_ingest.json"
QUICK_DIR = ROOT / "artifacts" / "bench"


def _time(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def bench(systems, specs, n_frames: int, *, chunk: int,
          repeats: int = 3, log=print) -> dict:
    frames, truth, scene = make_camera_stream(specs, n_frames, hw=32,
                                              seed=7)
    for floor in (0.8, None):
        spec_q = QuerySpec(metadata_eq={}, predicates=[
            PredicateClause(s.name, min_accuracy=floor) for s in specs])
        try:
            plan = plan_query(systems, spec_q, joint=True)
            break
        except ValueError:
            log(f"[bench] no cascade clears min_accuracy={floor}; "
                f"relaxing")

    # ---------------------------------------------------------- ingest --
    pipe = build_ingest_pipeline(plan.cascades, n_frames, chunk=chunk)
    ids = np.arange(n_frames)
    pipe.ingest(frames[:chunk], ids[:chunk])          # warm the jit
    pipe2 = build_ingest_pipeline(plan.cascades, n_frames, chunk=chunk)
    t_ingest = _time(lambda: pipe2.run(frames, ids))
    pipe = pipe2                                      # the timed, full run
    st = pipe.stats
    log(f"[bench] ingest {n_frames} frames in {t_ingest:.2f}s "
        f"({n_frames / t_ingest:.0f} frames/s): {st.skipped} aliased, "
        f"{st.refs} scored, {st.decided_labels} labels decided")

    # ---------------------------------------------------- three queries --
    def run(index_mode=None):
        eng = ScanEngine(frames, chunk=chunk)
        if index_mode is None:
            return eng, (lambda: (eng.reset_cache(),
                                  eng.execute(plan.cascades, {}))[1])
        p = plan_query(systems, spec_q, joint=True, index=pipe.index,
                       index_mode=index_mode)
        return eng, (lambda: (eng.reset_cache(),
                              indexed_execute(eng, p))[1])

    results, times = {}, {}
    for mode in (None, "exact", "approx"):
        name = mode or "cold"
        eng, go = run(mode)
        results[name] = go()                          # warm + stats
        times[name] = min(_time(go) for _ in range(repeats))

    cold, exact, approx = (results[k] for k in ("cold", "exact",
                                                "approx"))
    ref = naive_scan(frames, plan.cascades, chunk=chunk)
    exact_identical = (bool(np.array_equal(exact.indices, cold.indices))
                       and bool(np.array_equal(cold.indices, ref)))
    if not exact_identical:
        raise SystemExit(
            "[bench] EXACTNESS GATE FAILED: indexed exact-mode row set "
            "diverged from the cold scan / naive reference")

    inter = len(np.intersect1d(approx.indices, cold.indices))
    recall = inter / max(len(cold.indices), 1)
    prec = inter / max(len(approx.indices), 1)
    evals = {k: int(r.stats.rows_evaluated) for k, r in results.items()}
    elim = {k: round(100 * (1 - evals[k] / max(evals["cold"], 1)), 1)
            for k in ("exact", "approx")}
    log(f"[bench] rows evaluated: cold {evals['cold']} | exact "
        f"{evals['exact']} (-{elim['exact']}%) | approx "
        f"{evals['approx']} (-{elim['approx']}%) at recall "
        f"{recall:.3f}")

    # ------------------------------------------- index-seeded serving --
    from repro.serve.batcher import Request
    from repro.serve.service import AsyncCascadeService

    svc = AsyncCascadeService(frames,
                              {c.concept: c for c in plan.cascades},
                              shards=2, ingest_index=pipe.index)
    rid = 0
    for c in plan.cascades:
        for row in range(n_frames):
            svc.submit(c.concept, Request(rid=rid, payload=row))
            rid += 1
    agg = svc.summary()
    log(f"[bench] serving: {agg['requests']} requests -> "
        f"{agg['store_hits']} answered from the ingest index "
        f"({100 * agg['store_hit_rate']:.0f}%)")

    return {
        "frames": n_frames,
        "scenes": int(scene.max() + 1),
        "chunk": chunk,
        "predicates": len(specs),
        "min_accuracy": floor,
        "prune_margin": pipe.index.prune_margin,
        "skip_threshold": pipe.skip_threshold,
        "ingest_s": round(t_ingest, 4),
        "ingest_frames_per_s": round(n_frames / t_ingest, 1),
        "skip_aliased": st.skipped,
        "skip_aliased_frac": round(st.skipped / n_frames, 3),
        "refs_scored": st.refs,
        "stage0_scores": st.stage0_scores,
        "decided_labels": st.decided_labels,
        "matches_cold": int(len(cold.indices)),
        "exact_identical": exact_identical,
        "rows_evaluated": evals,
        "invocations_eliminated_exact_pct": elim["exact"],
        "invocations_eliminated_approx_pct": elim["approx"],
        "approx_recall_vs_cold": round(recall, 4),
        "approx_precision_vs_cold": round(prec, 4),
        "measured_recall_per_concept": {
            s.name: round(pipe.index.measured_recall(s.name,
                                                     truth[:, k]), 4)
            for k, s in enumerate(specs)},
        "cold_s": round(times["cold"], 4),
        "exact_s": round(times["exact"], 4),
        "approx_s": round(times["approx"], 4),
        "speedup_exact_x": round(times["cold"] / times["exact"], 2),
        "speedup_approx_x": round(times["cold"] / times["approx"], 2),
        "serving": {
            "requests": int(agg["requests"]),
            "store_hits": int(agg["store_hits"]),
            "store_hit_rate": round(agg["store_hit_rate"], 4),
            "rows_evaluated": int(agg["rows_evaluated"]),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller stream/training (CI smoke); writes "
                         "under artifacts/bench/, never the headline")
    ap.add_argument("--chunk", type=int, default=64)
    args = ap.parse_args()

    import jax
    specs = DEFAULT_PREDICATES[:3]
    systems = build_systems(specs, steps=30 if args.quick else 60,
                            n_train=160 if args.quick else 240, hw=32)
    n_frames = 384 if args.quick else 1536

    report = {
        "backend": jax.default_backend(),
        "query": "SELECT frames WHERE "
                 + " AND ".join(f"contains({s.name})" for s in specs),
        "metric": "rows evaluated through any cascade stage (model "
                  "invocations) for the same planned query: cold scan "
                  "vs ingest-indexed exact/approx modes",
        **bench(systems, specs, n_frames, chunk=args.chunk),
    }
    if args.quick:
        QUICK_DIR.mkdir(parents=True, exist_ok=True)
        out = QUICK_DIR / OUT.with_suffix(".quick.json").name
    else:
        out = OUT
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}  (approx mode eliminates "
          f"{report['invocations_eliminated_approx_pct']}% of "
          f"invocations at recall {report['approx_recall_vs_cold']}, "
          f"exact mode bit-identical: {report['exact_identical']})")


if __name__ == "__main__":
    main()
