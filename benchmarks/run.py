"""Benchmark entry point — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (and writes artifacts/bench/).

  PYTHONPATH=src python -m benchmarks.run [--predicates 3] [--force]
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--predicates", type=int, default=10,
                    help="number of binary predicates (paper: 10); grids "
                         "are trained on first use and cached under "
                         "artifacts/bench/")
    ap.add_argument("--force", action="store_true",
                    help="retrain model grids (ignore cache)")
    args = ap.parse_args()

    from benchmarks import paper_tables, roofline
    from benchmarks.common import ART, Csv, get_states

    csv = Csv()
    print("name,us_per_call,derived")
    states = get_states(args.predicates, force=args.force)
    paper_tables.bench_speedups(states, csv)
    paper_tables.bench_scenarios(states, csv)
    paper_tables.bench_transforms(states, csv)
    paper_tables.bench_depth(states, csv)
    paper_tables.bench_fig8_frontier_shift(states, csv)
    paper_tables.bench_cascade_space(states, csv)
    paper_tables.bench_eval_speed(csv)
    paper_tables.bench_executor(csv)
    paper_tables.bench_transform_kernel(csv)
    roofline.bench_roofline(csv)
    csv.write(ART / "results.csv")
    print(f"\nwrote {ART / 'results.csv'}")


if __name__ == "__main__":
    main()
