"""End-to-end driver: train a ~100M-class LM (reduced here to a few-M
smoke config so it runs on this 1-core container; pass --full on a real
fleet) for a few hundred steps with the fault-tolerant runtime —
checkpoints, failure injection + recovery, straggler detection, optional
gradient compression.

  PYTHONPATH=src python examples/train_lm.py --arch mamba2-130m \
      --steps 200 [--compress topk] [--inject-failure 50]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    if "--steps" not in " ".join(sys.argv):
        sys.argv += ["--steps", "200"]
    main()
