"""Quickstart: the full TAHOMA loop on one binary predicate, end to end.

1. build a labeled corpus (synthetic stand-in for an ImageNet category);
2. system initialization (paper Fig. 2): train the A x F model grid,
   calibrate per-model decision thresholds, profile costs;
3. enumerate + evaluate ~10^4-10^5 cascades, compute the Pareto frontier
   under a deployment scenario;
4. select a cascade for the user's accuracy constraint and run a
   content-based query through it.

  PYTHONPATH=src python examples/quickstart.py [--scenario CAMERA]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.configs.base import TahomaCNNConfig  # noqa: E402
from repro.core.cascade import spec_levels  # noqa: E402
from repro.core.pipeline import initialize_system  # noqa: E402
from repro.core.query import BinaryPredicate, Corpus, run_query  # noqa: E402
from repro.core.selector import pareto_set, select  # noqa: E402
from repro.core.transforms import representation_space  # noqa: E402
from repro.data.synthetic import (DEFAULT_PREDICATES, make_corpus,  # noqa: E402
                                  three_way_split)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="CAMERA",
                    choices=["INFER_ONLY", "ARCHIVE", "ONGOING", "CAMERA"])
    ap.add_argument("--min-accuracy", type=float, default=0.85)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test scale (CI): fewer models/images/steps")
    args = ap.parse_args()

    pred = DEFAULT_PREDICATES[1]
    print(f"== predicate: contains_object({pred.name}) ==")
    n_img = 240 if args.tiny else 480
    x, y = make_corpus(pred, n_img, hw=32, seed=0)
    splits = three_way_split(x, y, seed=1)

    print("initializing system (training model grid)...")
    t0 = time.time()
    if args.tiny:
        archs = [TahomaCNNConfig(1, 8, 16)]
        reps = representation_space([8, 16, 32], ("rgb", "gray"))
        steps = 40
    else:
        archs = [TahomaCNNConfig(1, 8, 16), TahomaCNNConfig(2, 16, 16)]
        reps = representation_space([8, 16, 32])
        steps = 150
    sys_ = initialize_system(*splits, archs=archs, reps=reps, steps=steps)
    print(f"  {len(sys_.bank.entries)} models in {time.time()-t0:.0f}s")

    space = sys_.cascade_space(args.scenario)
    par = pareto_set(space)
    print(f"cascades evaluated: {len(space):,}; Pareto frontier: "
          f"{len(par)} points "
          f"(acc {space.acc[par].min():.3f}-{space.acc[par].max():.3f})")
    for i in par[:6]:
        print(f"  acc={space.acc[i]:.3f} {space.throughput[i]:9.0f} img/s  "
              f"{space.describe(int(i), sys_.bank.names, sys_.targets)}")

    floor = min(args.min_accuracy, float(space.acc.max()) - 0.01)
    sel = select(space, min_accuracy=floor)
    print(f"\nselected (acc>={floor:.2f}): acc={sel.accuracy:.3f} "
          f"{sel.throughput:.0f} img/s under {args.scenario}")
    levels = spec_levels(space, sel.index, sys_.p_low, sys_.p_high)

    def executor(imgs):
        import jax.numpy as jnp
        from repro.core.transforms import apply_transform
        from repro.models.cnn import cnn_predict_proba
        out = np.zeros(len(imgs), np.int32)
        active = np.ones(len(imgs), bool)
        for m, lo, hi in levels:
            e = sys_.bank.entries[m]
            s = np.asarray(cnn_predict_proba(
                e.params, apply_transform(jnp.asarray(imgs), e.rep)))
            if lo is None:
                out[active] = (s >= 0.5)[active]
                active[:] = False
            else:
                dec = active & ((s <= lo) | (s >= hi))
                out[dec] = (s >= hi)[dec]
                active &= ~dec
        return out

    ev_x, ev_y = splits[2]
    corpus = Corpus(images=ev_x,
                    metadata={"city": np.where(np.arange(len(ev_x)) % 2,
                                               "detroit", "akron")})
    ids = run_query(corpus, metadata_eq={"city": "detroit"},
                    binary_preds=[BinaryPredicate(pred.name, executor)])
    prec = ev_y[ids].mean() if len(ids) else float("nan")
    print(f"\nquery: city='detroit' AND contains_object({pred.name})")
    print(f"  -> {len(ids)} matches, precision vs ground truth: {prec:.2f}")


if __name__ == "__main__":
    main()
