"""Serving example: a request stream of images flows through the batcher
into the TPU-native batched cascade executor (two-phase compaction), with
per-request latency accounting — the online half of the paper's system.

  PYTHONPATH=src python examples/serve_cascade.py [--requests 512]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import TahomaCNNConfig  # noqa: E402
from repro.core.executor import calibrate_capacity, run_cascade_batch  # noqa: E402
from repro.core.transforms import Representation, apply_transform  # noqa: E402
from repro.data.synthetic import DEFAULT_PREDICATES, make_corpus  # noqa: E402
from repro.core.pipeline import train_cnn  # noqa: E402
from repro.models.cnn import cnn_predict_proba  # noqa: E402
from repro.serve.batcher import Batcher, Request  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch-size", type=int, default=64)
    args = ap.parse_args()

    pred = DEFAULT_PREDICATES[1]
    x, y = make_corpus(pred, 600, hw=32, seed=0)
    tr_x, tr_y = x[:300], y[:300]

    print("training a 2-level cascade (small gray@16px -> full rgb@32px)...")
    rep_fast = Representation(16, "gray")
    rep_full = Representation(32, "rgb")
    fast_arch = TahomaCNNConfig(1, 8, 16, input_hw=16, input_channels=1)
    full_arch = TahomaCNNConfig(2, 16, 32, input_hw=32, input_channels=3)
    p_fast = train_cnn(fast_arch, np.asarray(
        apply_transform(jnp.asarray(tr_x), rep_fast)), tr_y, steps=150)
    p_full = train_cnn(full_arch, np.asarray(
        apply_transform(jnp.asarray(tr_x), rep_full)), tr_y, steps=200)

    # calibrate level-2 capacity from the observed uncertain fraction
    s = np.asarray(cnn_predict_proba(p_fast, apply_transform(
        jnp.asarray(x[300:430]), rep_fast)))
    unc = float(((s > 0.2) & (s < 0.8)).mean())
    cap = calibrate_capacity(unc, args.batch_size)
    print(f"level-1 uncertain fraction {unc:.2f} -> level-2 capacity {cap}")

    # passing Representations (not callables) turns on pyramid source
    # derivation: level inputs come from the previous level's source
    # tensor instead of re-transforming raw images (DESIGN.md §3.4)
    cascade = jax.jit(lambda imgs: run_cascade_batch(
        imgs,
        [lambda z: cnn_predict_proba(p_fast, z),
         lambda z: cnn_predict_proba(p_full, z)],
        [(0.2, 0.8), (None, None)],
        [rep_fast, rep_full],
        capacities=[cap]))

    def run_batch(payloads):
        labels, stats = cascade(jnp.stack(payloads))
        return list(np.asarray(labels))

    batcher = Batcher(run_batch, batch_size=args.batch_size,
                      max_wait_s=0.005)
    stream = x[300:300 + args.requests]
    truth = y[300:300 + args.requests]
    t0 = time.perf_counter()
    results = []
    for i, img in enumerate(stream):
        r = Request(i, jnp.asarray(img))
        batcher.submit(r)
        results.append(r)
        batcher.poll()
    batcher.drain()
    dt = time.perf_counter() - t0
    preds = np.array([r.result for r in results])
    lat = np.array(batcher.stats.latencies) * 1e3
    print(f"\nserved {len(stream)} requests in {dt:.2f}s "
          f"({len(stream)/dt:.0f} img/s)")
    print(f"batches={batcher.stats.batches} padded={batcher.stats.padded_slots}")
    print(f"latency p50={np.percentile(lat, 50):.1f}ms "
          f"p99={np.percentile(lat, 99):.1f}ms")
    print(f"accuracy vs ground truth: {(preds == truth).mean():.3f}")


if __name__ == "__main__":
    main()
