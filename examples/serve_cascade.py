"""Serving example: a MIXED request stream ("does this frame contain
a?" / "...contain b?") flows through CascadeService, which routes each
predicate's requests into its own fixed-shape batch over a jitted
cascade executor (engine/scan.make_batch_runner) — the online face of
the query engine, with per-request latency accounting.

  PYTHONPATH=src python examples/serve_cascade.py [--requests 256]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import TahomaCNNConfig  # noqa: E402
from repro.core.executor import calibrate_capacity  # noqa: E402
from repro.core.pipeline import train_cnn  # noqa: E402
from repro.core.transforms import Representation, apply_transform  # noqa: E402
from repro.data.synthetic import DEFAULT_PREDICATES, make_corpus  # noqa: E402
from repro.engine.scan import CompiledCascade, make_batch_runner  # noqa: E402
from repro.models.cnn import cnn_predict_proba  # noqa: E402
from repro.serve.batcher import CascadeService, Request  # noqa: E402


def build_cascade(spec, batch_size: int, *, hw: int = 32, steps: int = 150,
                  n_train: int = 300):
    """Train a 2-level cascade (small gray@16 -> full rgb@hw) for one
    predicate and package it as a CompiledCascade."""
    x, y = make_corpus(spec, n_train + 130, hw=hw, seed=0)
    tr_x, tr_y = x[:n_train], y[:n_train]
    rep_fast = Representation(16, "gray")
    rep_full = Representation(hw, "rgb")
    fast_arch = TahomaCNNConfig(1, 8, 16, input_hw=16, input_channels=1)
    full_arch = TahomaCNNConfig(2, 16, 32, input_hw=hw, input_channels=3)
    p_fast = train_cnn(fast_arch, np.asarray(
        apply_transform(jnp.asarray(tr_x), rep_fast)), tr_y, steps=steps)
    p_full = train_cnn(full_arch, np.asarray(
        apply_transform(jnp.asarray(tr_x), rep_full)), tr_y,
        steps=steps + 50)
    # calibrate level-2 capacity from the observed uncertain fraction
    s = np.asarray(cnn_predict_proba(p_fast, apply_transform(
        jnp.asarray(x[n_train:]), rep_fast)))
    unc = float(((s > 0.2) & (s < 0.8)).mean())
    cap = calibrate_capacity(unc, batch_size)
    print(f"  {spec.name}: uncertain fraction {unc:.2f} -> "
          f"level-2 capacity {cap}")
    return CompiledCascade(
        concept=spec.name, cascade_id=("serve-2level", spec.name),
        reps=[rep_fast, rep_full],
        model_fns=[lambda z, p=p_fast: cnn_predict_proba(p, z),
                   lambda z, p=p_full: cnn_predict_proba(p, z)],
        thresholds=[(0.2, 0.8), (None, None)], capacities=[cap])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test scale (CI)")
    args = ap.parse_args()
    if args.tiny:
        args.requests = min(args.requests, 48)
        args.batch_size = min(args.batch_size, 16)
    steps = 40 if args.tiny else 150

    specs = (DEFAULT_PREDICATES[1], DEFAULT_PREDICATES[4])
    print("training one 2-level cascade per predicate...")
    cascades = {s.name: build_cascade(s, args.batch_size, steps=steps)
                for s in specs}
    service = CascadeService(
        {c: make_batch_runner(casc, args.batch_size)
         for c, casc in cascades.items()},
        batch_size=args.batch_size, max_wait_s=0.005)

    # mixed stream: each request asks about ONE predicate's concept
    streams = {s.name: make_corpus(s, 300 + args.requests, hw=32, seed=9)
               for s in specs}
    t0 = time.perf_counter()
    results = []
    for i in range(args.requests):
        spec = specs[i % len(specs)]
        x, y = streams[spec.name]
        img = x[300 + i]
        r = Request(i, jnp.asarray(img))
        service.submit(spec.name, r)
        results.append((spec.name, r, int(y[300 + i])))
        service.poll()
    service.drain()
    dt = time.perf_counter() - t0

    lat = np.array(service.latencies()) * 1e3
    print(f"\nserved {args.requests} mixed requests in {dt:.2f}s "
          f"({args.requests / dt:.0f} img/s)")
    for c, st in service.stats.items():
        acc = np.mean([int(r.result) == y for cc, r, y in results
                       if cc == c])
        print(f"  {c}: batches={st.batches} padded={st.padded_slots} "
              f"accuracy={acc:.3f}")
    print(f"latency p50={np.percentile(lat, 50):.1f}ms "
          f"p99={np.percentile(lat, 99):.1f}ms")


if __name__ == "__main__":
    main()
