"""Serving example: a MIXED request stream ("does this frame contain
a?" / "...contain b?") over a resident frame corpus, served by the
shard-aware AsyncCascadeService (DESIGN.md §10): requests hash-route to
per-shard device queues, a deadline wheel flushes bucketed batches,
labels commit to shard-owned virtual columns (re-asked frames answer
with zero model invocations), and pooled pyramid levels are shared
across concepts through the cross-query representation cache.

  PYTHONPATH=src python examples/serve_cascade.py [--requests 256]
      [--shards 4] [--repeat 0.4] [--sync] [--host]

``--sync`` falls back to the synchronous-polling CascadeService
(serve/batcher.py) — the pre-§10 serving path, kept as the baseline
benchmarks/bench_serve.py prices the async subsystem against.
``--host`` drives the async service with the wall-clock event host
(serve/host.py, DESIGN.md §12.1): a timer-parked daemon thread fires
deadline flushes autonomously, so the client never calls ``poll()``.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import TahomaCNNConfig  # noqa: E402
from repro.core.executor import calibrate_capacity  # noqa: E402
from repro.core.pipeline import build_cascade_service, train_cnn  # noqa: E402
from repro.core.transforms import Representation, apply_transform  # noqa: E402
from repro.data.synthetic import DEFAULT_PREDICATES, make_corpus  # noqa: E402
from repro.engine.scan import CompiledCascade  # noqa: E402
from repro.models.cnn import cnn_predict_proba  # noqa: E402
from repro.serve.batcher import Request  # noqa: E402


def build_cascade(spec, batch_size: int, *, hw: int = 32, steps: int = 150,
                  n_train: int = 300):
    """Train a 2-level cascade (small gray@16 -> full rgb@hw) for one
    predicate and package it as a CompiledCascade."""
    x, y = make_corpus(spec, n_train + 130, hw=hw, seed=0)
    tr_x, tr_y = x[:n_train], y[:n_train]
    rep_fast = Representation(16, "gray")
    rep_full = Representation(hw, "rgb")
    fast_arch = TahomaCNNConfig(1, 8, 16, input_hw=16, input_channels=1)
    full_arch = TahomaCNNConfig(2, 16, 32, input_hw=hw, input_channels=3)
    p_fast = train_cnn(fast_arch, np.asarray(
        apply_transform(jnp.asarray(tr_x), rep_fast)), tr_y, steps=steps)
    p_full = train_cnn(full_arch, np.asarray(
        apply_transform(jnp.asarray(tr_x), rep_full)), tr_y,
        steps=steps + 50)
    # calibrate level-2 capacity from the observed uncertain fraction
    # (a sync-batcher knob: the async service runs full-width levels)
    s = np.asarray(cnn_predict_proba(p_fast, apply_transform(
        jnp.asarray(x[n_train:]), rep_fast)))
    unc = float(((s > 0.2) & (s < 0.8)).mean())
    cap = calibrate_capacity(unc, batch_size)
    print(f"  {spec.name}: uncertain fraction {unc:.2f} -> "
          f"level-2 capacity {cap}")
    return CompiledCascade(
        concept=spec.name, cascade_id=("serve-2level", spec.name),
        reps=[rep_fast, rep_full],
        model_fns=[lambda z, p=p_fast: cnn_predict_proba(p, z),
                   lambda z, p=p_full: cnn_predict_proba(p, z)],
        thresholds=[(0.2, 0.8), (None, None)], capacities=[cap])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--shards", type=int, default=None,
                    help="shard-queue count (default: one per device)")
    ap.add_argument("--repeat", type=float, default=0.4,
                    help="fraction of requests re-asking an earlier frame")
    ap.add_argument("--pace", type=float, default=0.002,
                    help="inter-arrival gap in seconds (0 = burst); a "
                         "paced stream lets deadlines fire and deliveries "
                         "land mid-stream, so re-asked frames hit the "
                         "virtual columns")
    ap.add_argument("--sync", action="store_true",
                    help="legacy synchronous batcher (serve/batcher.py)")
    ap.add_argument("--host", action="store_true",
                    help="drive the async service with the wall-clock "
                         "event host (no caller poll())")
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test scale (CI)")
    args = ap.parse_args()
    if args.tiny:
        args.requests = min(args.requests, 48)
        args.batch_size = min(args.batch_size, 16)
    steps = 40 if args.tiny else 150

    specs = (DEFAULT_PREDICATES[1], DEFAULT_PREDICATES[4])
    print("training one 2-level cascade per predicate...")
    cascades = {s.name: build_cascade(s, args.batch_size, steps=steps)
                for s in specs}

    # resident candidate corpus + ground truth per concept
    n_corpus = max(args.requests, 64)
    frames = {s.name: make_corpus(s, n_corpus, hw=32, seed=9)
              for s in specs}
    corpus = np.concatenate([frames[s.name][0] for s in specs])
    offset = {s.name: i * n_corpus for i, s in enumerate(specs)}

    mode = "sync" if args.sync else "async"
    service = build_cascade_service(
        corpus, cascades, mode=mode, shards=args.shards,
        batch_size=args.batch_size, max_wait_s=0.005)
    print(f"serving mode: {mode}"
          + ("" if args.sync else
             f"  ({service.n_shards} shard queues over "
             f"{len(set(service.devices))} devices)"))
    if mode == "async":
        n = service.warmup()      # no compile stalls under live traffic
        print(f"warmed {n} executables")
    host = None
    if args.host and mode == "async":
        from repro.serve import EventHost
        host = EventHost(service).start()
        print("event host started (deadlines fire without caller poll)")

    # mixed stream: each request asks about ONE predicate's concept;
    # a --repeat fraction re-asks an already-served frame (interactive
    # sessions revisit hot frames — the cross-query reuse scenario)
    rng = np.random.default_rng(13)
    results = []
    t0 = time.perf_counter()
    for i in range(args.requests):
        spec = specs[i % len(specs)]
        fresh = i < 8 or rng.uniform() >= args.repeat
        j = (i if fresh else int(rng.integers(0, i))) // len(specs)
        row = offset[spec.name] + j
        r = Request(i, row if mode == "async"
                    else jnp.asarray(corpus[row]))
        (host or service).submit(spec.name, r)
        results.append((spec.name, j, r))
        if host is None:
            service.poll()
        if args.pace:
            time.sleep(args.pace)
    if host is not None:
        host.wait_idle(60.0)      # event-driven: no poll, no drain
        host.stop()
    else:
        service.drain()
    dt = time.perf_counter() - t0

    lat = np.array(service.latencies()) * 1e3
    print(f"\nserved {args.requests} mixed requests in {dt:.2f}s "
          f"({args.requests / dt:.0f} img/s)")
    for c in service.concepts:
        y = frames[c][1]
        acc = np.mean([int(r.result) == int(y[j])
                       for cc, j, r in results if cc == c])
        if mode == "async":
            st = service.stats[c]
            print(f"  {c}: batches={st.batches} "
                  f"store_hits={st.store_hits} "
                  f"padded={st.padded_slots} accuracy={acc:.3f}")
        else:
            st = service.stats[c]
            print(f"  {c}: batches={st.batches} "
                  f"padded={st.padded_slots} accuracy={acc:.3f}")
    if mode == "async":
        summ = service.summary()
        print(f"store hit rate {summ['store_hit_rate']:.2f}  "
              f"repcache hit rate "
              f"{summ['repcache']['hit_rate']:.2f}  "
              f"deadline/size/drain flushes "
              f"{summ['deadline_flushes']}/{summ['size_flushes']}"
              f"/{summ['drain_flushes']}")
        p = summ["latency_ms"]
        print(f"latency p50={p['p50']}ms p95={p['p95']}ms "
              f"p99={p['p99']}ms  queue depth max="
              f"{summ['queue_depth']['max']}  in-flight max="
              f"{summ['in_flight']['max']}")
    else:
        print(f"latency p50={np.percentile(lat, 50):.1f}ms "
              f"p99={np.percentile(lat, 99):.1f}ms")


if __name__ == "__main__":
    main()
