"""The paper's technique on the ASSIGNED LM architectures: a predicate
cascade where a cheap truncated-context LM (token-domain analogue of the
paper's resolution scaling) answers contains-token(YES) queries and only
uncertain inputs fall through to the trusted LM. Thresholds come from the
same Algorithm 1 as the CNN cascades.

  PYTHONPATH=src python examples/lm_cascade_predicate.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.registry import smoke_config  # noqa: E402
from repro.core.lm_cascade import (LMLevel, calibrate, expected_cost,  # noqa: E402
                                   lm_predicate_score, run_lm_cascade)
from repro.models.factory import build_model  # noqa: E402
from repro.train.optimizer import adamw  # noqa: E402

YES, NO = 7, 13


def make_task(vocab, n, seq, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, vocab, (n, seq)).astype(np.int32)
    toks[toks == YES] = YES + 1
    labels = rng.integers(0, 2, n).astype(np.int32)
    for i in np.where(labels == 1)[0]:
        toks[i, rng.integers(0, seq - 1, size=3)] = YES
    return toks, labels


def train_level(arch, toks, labels, steps, seed=0):
    cfg = smoke_config(arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = adamw(3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, tb, yb):
        def loss_fn(p):
            logits, _, _ = model.forward(p, {"tokens": tb},
                                         remat_policy="none",
                                         logits_last_only=True)
            pair = logits[:, -1, jnp.asarray([YES, NO])]
            logp = jax.nn.log_softmax(pair.astype(jnp.float32), -1)
            return -jnp.mean(jnp.where(yb == 1, logp[:, 0], logp[:, 1]))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state, _ = opt.update(grads, state, params)
        return params, state, loss

    rng = np.random.default_rng(seed)
    for _ in range(steps):
        idx = rng.integers(0, len(toks), 16)
        params, state, _ = step(params, state, jnp.asarray(toks[idx]),
                                jnp.asarray(labels[idx]))
    return LMLevel(model=model, params=params, yes_token=YES, no_token=NO)


def main():
    vocab = smoke_config("deepseek-7b").vocab_size
    toks, labels = make_task(vocab, 360, 24)
    print("training cheap level (minitron smoke, 12-token context)...")
    small = train_level("minitron-4b", toks[:200, -12:], labels[:200], 150)
    small.max_context = 12
    print("training trusted level (deepseek-7b smoke, full context)...")
    trusted = train_level("deepseek-7b", toks[:200], labels[:200], 220,
                          seed=1)
    calibrate([small, trusted], toks[200:280], labels[200:280],
              prec_target=0.8)
    print(f"calibrated thresholds: p_low={small.p_low:.2f} "
          f"p_high={small.p_high:.2f}")

    ev_t, ev_y = toks[280:], labels[280:]
    preds, used = run_lm_cascade([small, trusted], ev_t)
    acc = (preds == ev_y).mean()
    acc_trusted = ((lm_predicate_score(trusted, ev_t) >= 0.5)
                   == ev_y).mean()
    cost = expected_cost([small, trusted], used, [1.0, 30.0])
    print(f"\ncascade accuracy {acc:.3f} (trusted-only {acc_trusted:.3f})")
    print(f"routed early: {(used == 0).mean():.0%}; expected cost "
          f"{cost:.1f} units vs trusted-only 31.0 "
          f"({31.0 / cost:.1f}x cheaper)")


if __name__ == "__main__":
    main()
