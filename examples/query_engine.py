"""End-to-end multi-predicate query through the query engine
(DESIGN.md §4, §11):

  SELECT frames WHERE cam = 0 AND contains(a) AND contains(b) AND
                       contains(c)

1. train one TAHOMA system (A x F grid -> thresholds -> cost profile ->
   evaluated cascade space) per concept;
2. plan: select the cascade SET under shared-representation costing
   (``--planner joint``, the default: per-predicate Pareto frontiers as
   candidate pools, shared pyramid levels priced once — DESIGN.md §11)
   or one cascade per predicate independently (``--planner
   independent``), order predicates by (marginal) cost/(1-selectivity),
   print the EXPLAIN-style physical plan;
3. execute: stream the corpus in chunks, ONE shared representation
   pyramid per chunk covering exactly the plan's level set, cascades
   only on rows surviving earlier predicates — and compare wall-clock +
   row set against naive per-predicate full scans;
4. re-run a re-planned query to show partial virtual-column reuse.

``--adaptive`` attaches the planner's OnlineReorderer: the engine feeds
observed per-flush selectivities back and re-orders surviving predicates
mid-scan when the eval-split estimates drift (row sets stay
bit-identical — DESIGN.md §11.3).

With ``--shards N`` the survivor set is partitioned across N shard
executors (DESIGN.md §9: pmap lockstep over the host's devices; set
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to simulate a
multi-chip host on CPU) and EXPLAIN additionally prints the shard
layout. Row sets are bit-identical to the unsharded engine.

  PYTHONPATH=src python examples/query_engine.py [--scenario CAMERA]
                                                 [--planner joint]
                                                 [--shards N]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# simulate a multi-chip host on CPU for the sharded path; the flag must
# land before the first jax import (the repro imports below pull jax in)
from repro.launch.devsim import force_host_devices  # noqa: E402

force_host_devices(8, when_flag="--shards")

import numpy as np  # noqa: E402

from repro.configs.base import TahomaCNNConfig  # noqa: E402
from repro.core.pipeline import build_scan_engine, initialize_system  # noqa: E402
from repro.core.transforms import Representation  # noqa: E402
from repro.data.synthetic import (DEFAULT_PREDICATES, make_corpus,  # noqa: E402
                                  make_multi_corpus, three_way_split)
from repro.engine import (PredicateClause, QuerySpec,  # noqa: E402
                          naive_scan, plan_query)


EXPLAIN_HELP = """\
EXPLAIN output (PhysicalPlan.explain, DESIGN.md §4.1/§11.2):
  per predicate:  the chosen cascade, its estimated accuracy, standalone
    cost/row, selectivity, ordering rank cost/(1-sel), and the fraction
    of rows reaching it under the plan order.
  joint plans add per predicate:  'levels={...}' the pyramid levels the
    cascade touches; 'shared={...}' the levels inherited from EARLIER
    predicates (materialized once per chunk, free here); 'rep/row
    marginal X vs standalone Y' the representation cost actually charged
    under sharing vs the §VI standalone price; 'infer/row' the expected
    pure-inference cost.
  joint plans add a summary:  'shared-representation savings' = unshared
    minus joint est. cost/row, and the pyramid level set the engine will
    materialize once per chunk (== PhysicalPlan.level_set + raw base).
"""


def main():
    ap = argparse.ArgumentParser(
        epilog=EXPLAIN_HELP,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--scenario", default="CAMERA",
                    choices=["INFER_ONLY", "ARCHIVE", "ONGOING", "CAMERA"])
    ap.add_argument("--min-accuracy", type=float, default=0.8)
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--planner", default="joint",
                    choices=["joint", "independent"],
                    help="joint = select the cascade SET under shared-"
                         "representation costing (DESIGN.md §11); "
                         "independent = per-predicate Pareto selection")
    ap.add_argument("--adaptive", action="store_true",
                    help="refine selectivities online: re-order "
                         "surviving predicates mid-scan when observed "
                         "per-flush selectivity drifts from the "
                         "eval-split estimate (bit-identical rows)")
    ap.add_argument("--shards", type=int, default=0,
                    help="partition the scan across N shard executors "
                         "(0 = single-host engine)")
    ap.add_argument("--shard-strategy", default="range",
                    choices=["range", "hash"])
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test scale (CI)")
    args = ap.parse_args()

    hw = 32
    if args.tiny:
        specs = DEFAULT_PREDICATES[:2]
        n_train, n_query, steps = 200, 192, 40
        reps = [Representation(8, "gray"), Representation(16, "gray"),
                Representation(hw, "rgb")]
        archs = [TahomaCNNConfig(1, 8, 16)]
    else:
        specs = DEFAULT_PREDICATES[:3]
        n_train, n_query, steps = 360, 480, 100
        reps = [Representation(8, "gray"), Representation(8, "rgb"),
                Representation(16, "gray"), Representation(16, "rgb"),
                Representation(hw, "gray"), Representation(hw, "rgb")]
        archs = [TahomaCNNConfig(1, 8, 16)]

    print(f"== predicates: {', '.join(s.name for s in specs)} ==")
    print("initializing one TAHOMA system per concept...")
    t0 = time.time()
    systems = {}
    for spec in specs:
        x, y = make_corpus(spec, n_train, hw=hw, seed=0)
        systems[spec.name] = initialize_system(
            *three_way_split(x, y, seed=1), archs, reps, steps=steps)
    print(f"  {sum(len(s.bank.entries) for s in systems.values())} models "
          f"in {time.time() - t0:.0f}s")

    # the queried corpus carries all predicate signals independently
    qx, qlabels = make_multi_corpus(specs, n_query, hw=hw, seed=7,
                                    positive_rate=0.4)
    metadata = {"cam": np.arange(n_query) % 2}

    spec_q = QuerySpec(
        metadata_eq={"cam": 0},
        predicates=[PredicateClause(s.name, min_accuracy=args.min_accuracy)
                    for s in specs])
    plan = plan_query(systems, spec_q, scenario=args.scenario,
                      metadata=metadata, joint=args.planner == "joint")

    engine = build_scan_engine(qx, metadata, shards=args.shards,
                               chunk=args.chunk,
                               strategy=args.shard_strategy)
    shard_plan = (engine.plan_for(plan.cascades, plan.metadata_eq)
                  if args.shards else None)
    print()
    print(plan.explain(n_rows=n_query, shard_plan=shard_plan))

    monitor = None
    if args.adaptive:
        if args.shards:
            # re-ordering would desync the lockstep supersteps for zero
            # dispatch savings (engine/sharded.py docstring)
            print("note: --adaptive is a serial-engine feature and is "
                  "ignored with --shards")
        else:
            from repro.engine import OnlineReorderer
            monitor = OnlineReorderer.from_plan(plan,
                                                min_rows=args.chunk // 2)

    t0 = time.perf_counter()
    if shard_plan is not None:           # execute the layout EXPLAIN shows
        res = engine.execute(plan.cascades, plan.metadata_eq,
                             shard_plan=shard_plan)
    else:
        res = engine.execute(plan.cascades, plan.metadata_eq,
                             monitor=monitor)
    t_engine = time.perf_counter() - t0

    t0 = time.perf_counter()
    ref = naive_scan(qx, plan.cascades, metadata, plan.metadata_eq,
                     chunk=args.chunk)
    t_naive = time.perf_counter() - t0

    identical = np.array_equal(res.indices, ref)
    print(f"\nengine: {len(res.indices)} rows in {t_engine:.2f}s | naive "
          f"full scans: {len(ref)} rows in {t_naive:.2f}s "
          f"({t_naive / max(t_engine, 1e-9):.1f}x) | identical rows: "
          f"{identical}")
    for s in res.stats.stages:
        print(f"  {s.concept}: {s.rows_in} in -> {s.rows_evaluated} "
              f"evaluated ({s.batches} batches, {s.rows_cached} cached)")
    if monitor is not None:
        print(f"  adaptive: {res.stats.reorders} mid-scan re-orderings "
              f"(observed selectivities: "
              + ", ".join(f"{c.concept}={monitor.refined(c.key):.2f}"
                          for c in plan.cascades) + ")")
    if args.shards:
        st = res.stats
        print(f"  shards: {st.plan.describe()}  backend={st.backend} "
              f"devices={st.n_devices} supersteps={st.supersteps}")
        for i, sh in enumerate(st.shards):
            print(f"    shard {i}: {sh.rows_scanned} rows -> "
                  f"{sh.rows_evaluated} evaluated ({sh.chunks} chunks)")
    if len(res.indices):
        tp = qlabels[res.indices].all(axis=1).mean()
        print(f"  precision vs ground truth (all predicates): {tp:.2f}")

    # re-planned query (reversed order): partial virtual columns kick in
    res2 = engine.execute(plan.cascades[::-1], plan.metadata_eq)
    reused = sum(s.rows_cached for s in res2.stats.stages)
    print(f"\nre-planned (reversed) query: identical rows="
          f"{np.array_equal(res2.indices, res.indices)}, "
          f"{reused} row-labels reused from virtual columns, "
          f"{res2.stats.rows_evaluated} newly evaluated")


if __name__ == "__main__":
    main()
