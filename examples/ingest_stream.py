"""Streaming ingest-time indexing end to end (DESIGN.md §14):

  camera stream -> IngestPipeline (temporal skip detector + stage-0
  candidate-concept index) -> indexed queries + index-seeded serving

1. train one TAHOMA system per concept and plan a multi-predicate query
   (the planned cascades are the physical cascades the index keys on);
2. ingest a simulated camera stream chunk-by-chunk: near-duplicate
   frames are skip-aliased to their reference frame and never scored;
   each reference frame gets one cheap stage-0 rung per concept (one
   shared pyramid per chunk via the fused ingest program), yielding
   exact stage-0 decided labels + an approximate candidate set;
3. query three ways and compare row sets + rows evaluated:
   cold scan | indexed 'exact' (bit-identical row set guaranteed — the
   exactness escape hatch re-verifies skip-aliased rows) | indexed
   'approx' (alias labels + candidate pruning at a measured-recall
   knob);
4. seed an AsyncCascadeService from the index: ingest-decided rows are
   answered at submit with zero model invocations (store_hits).

  PYTHONPATH=src python examples/ingest_stream.py [--tiny] [--no-skip]
                                                  [--frames N]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.configs.base import TahomaCNNConfig  # noqa: E402
from repro.core.pipeline import (build_cascade_service,  # noqa: E402
                                 build_ingest_pipeline, build_scan_engine,
                                 initialize_system)
from repro.core.transforms import Representation  # noqa: E402
from repro.data.synthetic import (DEFAULT_PREDICATES, make_camera_stream,  # noqa: E402
                                  make_corpus, three_way_split)
from repro.engine import (PredicateClause, QuerySpec,  # noqa: E402
                          plan_query)
from repro.engine.ingest import indexed_execute  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=960,
                    help="camera-stream length")
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--min-accuracy", type=float, default=0.8)
    ap.add_argument("--no-skip", action="store_true",
                    help="disable the temporal-difference skip detector")
    ap.add_argument("--top-k", type=int, default=None,
                    help="cap each frame's candidate set to the top-K "
                         "stage-0 margins (Focus-style)")
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test scale (CI)")
    args = ap.parse_args()

    hw = 32
    if args.tiny:
        specs = DEFAULT_PREDICATES[:2]
        n_train, steps = 200, 40
        n_frames = min(args.frames, 384)
        reps = [Representation(8, "gray"), Representation(16, "gray"),
                Representation(hw, "rgb")]
    else:
        specs = DEFAULT_PREDICATES[:3]
        n_train, steps = 360, 100
        n_frames = args.frames
        reps = [Representation(8, "gray"), Representation(8, "rgb"),
                Representation(16, "gray"), Representation(16, "rgb"),
                Representation(hw, "gray"), Representation(hw, "rgb")]
    archs = [TahomaCNNConfig(1, 8, 16)]

    print(f"== predicates: {', '.join(s.name for s in specs)} ==")
    print("initializing one TAHOMA system per concept...")
    t0 = time.time()
    systems = {}
    for spec in specs:
        x, y = make_corpus(spec, n_train, hw=hw, seed=0)
        systems[spec.name] = initialize_system(
            *three_way_split(x, y, seed=1), archs, reps, steps=steps)
    print(f"  trained in {time.time() - t0:.0f}s")

    # plan FIRST: the ingest index keys labels by the planned physical
    # cascades (CompiledCascade.key)
    spec_q = QuerySpec(metadata_eq={}, predicates=[
        PredicateClause(s.name, min_accuracy=args.min_accuracy)
        for s in specs])
    plan = plan_query(systems, spec_q, joint=True)

    frames, truth, scene = make_camera_stream(specs, n_frames, hw=hw,
                                              seed=7)
    print(f"\n== ingest: {n_frames} frames, {scene.max() + 1} scenes ==")
    pipe = build_ingest_pipeline(plan.cascades, n_frames,
                                 chunk=args.chunk, skip=not args.no_skip,
                                 top_k=args.top_k)
    t0 = time.perf_counter()
    ids = np.arange(n_frames)
    for lo in range(0, n_frames, args.chunk):    # simulated arrival
        pipe.ingest(frames[lo:lo + args.chunk], ids[lo:lo + args.chunk])
    t_ingest = time.perf_counter() - t0
    st = pipe.stats
    print(f"  {st.frames} frames in {t_ingest:.2f}s: {st.skipped} "
          f"skip-aliased, {st.refs} scored ({st.stage0_scores} stage-0 "
          f"scores), {st.decided_labels} labels decided exactly at "
          f"ingest")

    # -------------------------------------------------- three queries --
    def query(index_mode=None):
        eng = build_scan_engine(frames, chunk=args.chunk)
        if index_mode is None:
            t0 = time.perf_counter()
            res = eng.execute(plan.cascades, {})
            return res, time.perf_counter() - t0
        p = plan_query(systems, spec_q, joint=True, index=pipe.index,
                       index_mode=index_mode)
        t0 = time.perf_counter()
        res = indexed_execute(eng, p)
        return res, time.perf_counter() - t0

    cold, t_cold = query()
    exact, t_exact = query("exact")
    approx, t_approx = query("approx")
    print(f"\n== query: {' AND '.join(s.name for s in specs)} ==")
    explain = plan_query(systems, spec_q, joint=True, index=pipe.index,
                         index_mode="approx").explain(n_rows=n_frames)
    print(next(ln for ln in explain.splitlines() if "ingest index" in ln))
    print(f"  cold scan:      {len(cold.indices)} rows, "
          f"{cold.stats.rows_evaluated} rows evaluated, {t_cold:.2f}s")
    kept = 100 * (1 - exact.stats.rows_evaluated
                  / max(cold.stats.rows_evaluated, 1))
    print(f"  indexed exact:  {len(exact.indices)} rows, "
          f"{exact.stats.rows_evaluated} rows evaluated "
          f"(-{kept:.0f}%), {t_exact:.2f}s | bit-identical: "
          f"{np.array_equal(exact.indices, cold.indices)}")
    kept = 100 * (1 - approx.stats.rows_evaluated
                  / max(cold.stats.rows_evaluated, 1))
    inter = len(np.intersect1d(approx.indices, cold.indices))
    rec = [pipe.index.measured_recall(s.name, truth[:, k])
           for k, s in enumerate(specs)]
    print(f"  indexed approx: {len(approx.indices)} rows, "
          f"{approx.stats.rows_evaluated} rows evaluated "
          f"(-{kept:.0f}%), {t_approx:.2f}s | recall vs cold: "
          f"{inter / max(len(cold.indices), 1):.2f} | measured "
          f"per-concept recall: "
          + ", ".join(f"{s.name}={r:.2f}" for s, r in zip(specs, rec)))

    # -------------------------------------------- index-seeded serving --
    from repro.serve.batcher import Request

    svc = build_cascade_service(frames,
                                {c.concept: c for c in plan.cascades},
                                shards=2, ingest_index=pipe.index)
    concept = plan.cascades[0].concept
    col = pipe.index.decided.column(plan.cascades[0].key)
    rows = np.where(col >= 0)[0][:64]
    for i, r in enumerate(rows):
        svc.submit(concept, Request(rid=i, payload=int(r)))
    s = svc.stats[concept]
    print(f"\n== serving seeded from the index ==")
    print(f"  {s.requests} requests for ingest-decided rows -> "
          f"{s.store_hits} answered at submit ({s.rows_evaluated} rows "
          f"evaluated, {s.batches} batches dispatched)")


if __name__ == "__main__":
    main()
