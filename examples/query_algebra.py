"""Boolean expression-tree queries + a cross-camera temporal join
through the relational query algebra (engine/algebra.py, DESIGN.md §15):

  SELECT frames WHERE cam = 0
                  AND contains(a) AND (contains(b) OR NOT contains(c))

  SELECT pairs  FROM camA, camB
                WHERE camA contains(a) AND camB contains(a)
                  AND |t_A - t_B| <= delta

1. train one TAHOMA system per concept (as examples/query_engine.py);
2. plan: ``QuerySpec.where`` carries the expression tree into
   ``plan_query``, which normalizes it (De Morgan to NNF), annotates
   every node with cost/selectivity estimates, cost-orders children for
   short-circuiting (AND rank cost/(1-sel); OR uses the INVERTED rank
   cost/sel — a branch short-circuits on TRUE, so the rarely-true
   branch goes LAST), and prints the annotated plan TREE;
3. execute: positive-leaf runs lower onto single shared-pyramid engine
   calls, NOT leaves read decided-0 virtual columns, AND/OR thread
   survivor sets — compared for wall-clock AND bit-identical rows
   against (a) the same tree executed WITHOUT short-circuiting or
   ordering and (b) the per-row naive oracle;
4. join: the cheap side runs first (build side), surviving timestamps
   prune the probe side to rows inside some ±delta window (exact), and
   the pair set is checked against the nested-loop reference.

  PYTHONPATH=src python examples/query_algebra.py [--tiny] [--delta 2]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.configs.base import TahomaCNNConfig  # noqa: E402
from repro.core.pipeline import initialize_system  # noqa: E402
from repro.core.transforms import Representation  # noqa: E402
from repro.data.synthetic import (DEFAULT_PREDICATES, make_corpus,  # noqa: E402
                                  make_multi_corpus,
                                  make_two_camera_corpus,
                                  three_way_split)
from repro.engine import (And, Join, Not, Or, Pred, QuerySpec,  # noqa: E402
                          ScanEngine, execute_join, execute_tree,
                          naive_join_pairs, naive_tree_rows, plan_query)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test scale (CI)")
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--min-accuracy", type=float, default=0.8)
    ap.add_argument("--delta", type=float, default=2.0,
                    help="temporal join window |t_A - t_B| <= delta")
    args = ap.parse_args()

    hw = 32
    if args.tiny:
        specs = DEFAULT_PREDICATES[:2]
        n_train, n_query, steps = 200, 160, 40
    else:
        specs = DEFAULT_PREDICATES[:3]
        n_train, n_query, steps = 360, 384, 100
    reps = [Representation(8, "gray"), Representation(16, "gray"),
            Representation(hw, "rgb")]
    archs = [TahomaCNNConfig(1, 8, 16)]

    names = [s.name for s in specs]
    print(f"== concepts: {', '.join(names)} ==")
    print("initializing one TAHOMA system per concept...")
    t0 = time.time()
    systems = {}
    for spec in specs:
        x, y = make_corpus(spec, n_train, hw=hw, seed=0)
        systems[spec.name] = initialize_system(
            *three_way_split(x, y, seed=1), archs, reps, steps=steps)
    print(f"  done in {time.time() - t0:.0f}s")

    # ---------------------------------------------- expression tree ----
    if args.tiny:       # XOR: positives of exactly one concept
        where = Or(And(Pred(names[0]), Not(Pred(names[1]))),
                   And(Pred(names[1]), Not(Pred(names[0]))))
    else:
        where = And(Pred(names[0]),
                    Or(Pred(names[1]), Not(Pred(names[2]))))
    qx, _ = make_multi_corpus(specs, n_query, hw=hw, seed=7,
                              positive_rate=0.4)
    metadata = {"cam": np.arange(n_query) % 2}
    spec_q = QuerySpec(metadata_eq={"cam": 0}, where=where)
    plan = plan_query(systems, spec_q, scenario="CAMERA",
                      metadata=metadata)
    print()
    print(plan.explain(n_rows=n_query))

    baseline = ScanEngine(qx, metadata, chunk=args.chunk)
    res_un = execute_tree(baseline, plan, optimize=False)
    engine = ScanEngine(qx, metadata, chunk=args.chunk)
    res = execute_tree(engine, plan)    # last: EXPLAIN shows its actuals
    t0 = time.perf_counter()
    ref = naive_tree_rows(qx, where, plan.cascade_map(), metadata,
                          plan.metadata_eq, chunk=args.chunk)
    t_naive = time.perf_counter() - t0
    print(f"\noptimized tree:   {len(res.indices)} rows in "
          f"{res.seconds:.2f}s ({res.engine_calls} engine calls, "
          f"{res.rows_evaluated} rows evaluated)")
    print(f"unoptimized tree: {len(res_un.indices)} rows in "
          f"{res_un.seconds:.2f}s ({res_un.engine_calls} engine calls, "
          f"{res_un.rows_evaluated} rows evaluated)")
    print(f"naive per-row oracle: {len(ref)} rows in {t_naive:.2f}s")
    same = (np.array_equal(res.indices, ref)
            and np.array_equal(res_un.indices, ref))
    print(f"identical rows across all three: {same}")
    print("\nannotated plan after execution (est vs actual):")
    print(plan.explain(n_rows=n_query))

    # ----------------------------------------- cross-camera join ----
    needle = names[0]
    print(f"\n== temporal join: {needle}@camA and {needle}@camB within "
          f"±{args.delta} ==")
    (xa, _, ta), (xb, _, tb) = make_two_camera_corpus(
        specs, n_query // 2, hw=hw, seed=11, corr=0.6,
        dt_max=int(args.delta))
    meta_a, meta_b = {"t": ta}, {"t": tb}
    jtree = Join(Pred(needle), Pred(needle), delta_t=args.delta)
    jplan = plan_query(systems, QuerySpec(where=jtree), scenario="CAMERA",
                       metadata=(meta_a, meta_b))
    print(jplan.explain(n_rows=(len(xa), len(xb))))
    eng_a = ScanEngine(xa, meta_a, chunk=args.chunk)
    eng_b = ScanEngine(xb, meta_b, chunk=args.chunk)
    jres = execute_join((eng_a, eng_b), jplan)
    print(f"\npushdown join: {len(jres.pairs)} pairs in "
          f"{jres.seconds:.2f}s (probe side pruned to "
          f"{jplan.window_kept}/{len(xb)} rows inside a window)")
    # baseline: both sides in full, then the same hash join
    jres_un = execute_join((ScanEngine(xa, meta_a, chunk=args.chunk),
                            ScanEngine(xb, meta_b, chunk=args.chunk)),
                           jplan, optimize=False)
    ref_pairs = naive_join_pairs(
        (jres_un.left.indices, ta), (jres_un.right.indices, tb),
        args.delta)
    same_pairs = (np.array_equal(jres.pairs, ref_pairs)
                  and np.array_equal(jres_un.pairs, ref_pairs))
    print(f"no-pushdown join: {len(jres_un.pairs)} pairs in "
          f"{jres_un.seconds:.2f}s")
    print(f"identical pairs (pushdown, baseline, nested loop): "
          f"{same_pairs}")


if __name__ == "__main__":
    main()
